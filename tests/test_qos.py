"""Tests for the scheduler's QoS tier (ISSUE 7).

The load-bearing guarantees:

* ``priority``/``deadline_ms``/``degrade`` ride the wire contract but
  never change ``engine_key``/``batch_key`` or per-request numerics --
  a request that meets its deadline is bit-identical to the pure-FIFO
  scheduler;
* pickup is priority-then-FIFO with aging (batch traffic cannot
  starve); expired deadlines are shed at pickup with a machine-readable
  ``reason: "deadline"`` and **zero rollout work**; opted-in
  near-deadline requests degrade to the validated member-count floor,
  reported honestly;
* a solo straggler of a shape with a batch in flight parks once and
  joins the *next* batch of that key; cancelled members of an in-flight
  batch shrink the rollout onto an already-compiled smaller-batch
  executable when one is warm;
* the request-lifecycle bugfixes hold: cancel-while-queued runs zero
  rollouts, a timed-out ``close()`` unblocks every consumer with a
  terminal event, and engine builds never race evictions.
"""

import threading
import time

import jax
import numpy as np
import pytest

from repro.inference import ForecastEngine
from repro.inference import perturbations as perturblib
from repro.serving import transport
from repro.serving.cache import ExecutableCache
from repro.serving.scheduler import (EnginePool, ForecastScheduler,
                                     ModelPool, RequestSpec)

SPEC = RequestSpec(config="smoke", members=2, lead_steps=2, lead_chunk=2,
                   scored=True)


@pytest.fixture(scope="module")
def pool():
    return ModelPool()


class _WarmGate:
    """Instance-level wrap of ``sched.cache.warm_engine`` that blocks
    serving at a deterministic point (after pickup, before any compile
    or rollout), so tests can stage queue states without sleeps."""

    def __init__(self, sched, block_when=None):
        self.entered = threading.Event()
        self.release = threading.Event()
        self.abort = False
        self._block_when = block_when  # fn(args, kwargs) -> bool
        self._orig = sched.cache.warm_engine
        sched.cache.warm_engine = self._wrapped

    def _wrapped(self, *a, **k):
        if self._block_when is None or self._block_when(a, k):
            self.entered.set()
            assert self.release.wait(timeout=60), "gate never released"
            if self.abort:
                raise RuntimeError("aborted by test gate")
        return self._orig(*a, **k)


def _record_serve_order(sched):
    """Wrap ``_serve_batch`` to record pickup order (request ids)."""
    order = []
    orig = sched._serve_batch

    def wrapped(streams):
        order.extend(s.request_id for s in streams)
        return orig(streams)

    sched._serve_batch = wrapped
    return order


class TestQoSSpec:
    def test_qos_fields_validate(self):
        RequestSpec(**{**SPEC.to_dict(), "priority": "interactive",
                       "deadline_ms": 250.0, "degrade": True}).validate()
        with pytest.raises(ValueError, match="priority must be one of"):
            RequestSpec(**{**SPEC.to_dict(),
                           "priority": "urgent"}).validate()
        with pytest.raises(ValueError, match="deadline_ms must be"):
            RequestSpec(**{**SPEC.to_dict(),
                           "deadline_ms": "soon"}).validate()
        with pytest.raises(ValueError, match="deadline_ms must be"):
            RequestSpec(**{**SPEC.to_dict(),
                           "deadline_ms": -5}).validate()
        with pytest.raises(ValueError, match="degrade must be a boolean"):
            RequestSpec(**{**SPEC.to_dict(), "degrade": 1}).validate()

    def test_qos_fields_ride_the_wire_contract(self):
        d = {**SPEC.to_dict(), "priority": "interactive",
             "deadline_ms": 125.5, "degrade": True}
        spec = RequestSpec.from_dict(d)
        assert spec.priority == "interactive"
        assert spec.deadline_ms == 125.5
        assert spec.degrade is True
        assert spec.to_dict() == d

    def test_qos_fields_never_change_compiled_program_keys(self):
        base = SPEC
        qos = RequestSpec(**{**SPEC.to_dict(), "priority": "interactive",
                             "deadline_ms": 50.0, "degrade": True})
        # the whole point: QoS routes traffic, it must not fragment the
        # executable cache
        assert qos.engine_key() == base.engine_key()
        assert qos.batch_key() == base.batch_key()

    def test_degraded_members_is_validated_floor(self):
        spec = RequestSpec(**{**SPEC.to_dict(), "members": 8})
        dm = spec.degraded_members()
        assert 2 <= dm < spec.members
        assert perturblib.validate_member_count(
            dm, centered=True, cfg=spec.perturbation_config()) == []
        # ensemble transform needs 4 antithetic members: the floor obeys
        et = RequestSpec(**{**SPEC.to_dict(), "members": 8,
                            "perturb": "bred",
                            "ensemble_transform": True})
        dm_et = et.degraded_members()
        assert perturblib.validate_member_count(
            dm_et, centered=True, cfg=et.perturbation_config()) == []
        assert dm_et >= 4
        # nothing smaller validates -> serve what was asked
        assert RequestSpec(**{**SPEC.to_dict(),
                              "members": 2}).degraded_members() == 2


class TestPriorityAndAdmission:
    """One gated scheduler session covers priority-then-FIFO pickup,
    deadline shed, cancel-while-queued and the no-QoS bit-identity of
    served requests."""

    @pytest.fixture(scope="class")
    def qsched(self, pool):
        # aging disabled so pure priority ordering is observable
        s = ForecastScheduler(pool=pool, cache=ExecutableCache(),
                              max_concurrency=1, aging_ms=1e9)
        yield s
        s.close()

    @pytest.fixture(scope="class")
    def session(self, qsched):
        """Plug the single worker, stage a mixed queue, release, and
        hand the tests the observed outcomes."""
        order = _record_serve_order(qsched)
        gate = _WarmGate(qsched)
        plug = qsched.submit(
            RequestSpec(**{**SPEC.to_dict(), "seed": 100}))
        assert gate.entered.wait(timeout=60)
        b1 = qsched.submit(RequestSpec(**{**SPEC.to_dict(), "seed": 101}))
        dead = qsched.submit(RequestSpec(
            **{**SPEC.to_dict(), "seed": 102, "deadline_ms": 30.0}))
        c1 = qsched.submit(RequestSpec(**{**SPEC.to_dict(), "seed": 103}))
        c1.cancel()
        i1 = qsched.submit(RequestSpec(
            **{**SPEC.to_dict(), "seed": 104, "priority": "interactive"}))
        time.sleep(0.1)  # let dead's 30ms deadline expire while queued
        gate.release.set()
        results = {}
        for name, st in (("plug", plug), ("b1", b1), ("i1", i1),
                         ("c1", c1)):
            results[name] = st.result()
        with pytest.raises(transport.ServingError) as err:
            dead.result()
        return {"order": order, "results": results, "dead_err": err.value,
                "streams": {"plug": plug, "b1": b1, "dead": dead,
                            "c1": c1, "i1": i1}}

    def test_interactive_beats_batch_fifo_within_class(self, session):
        st = session["streams"]
        assert session["order"] == [st["plug"].request_id,
                                    st["i1"].request_id,
                                    st["b1"].request_id]

    def test_expired_deadline_shed_with_reason_and_no_rollout(
            self, session, qsched):
        err = session["dead_err"]
        assert err.reason == "deadline"
        assert "shed before rollout" in str(err)
        # zero rollout work: the shed request never reached a worker
        assert session["streams"]["dead"].request_id not in session["order"]
        assert qsched.stats()["qos"]["shed"] == {"batch": 1}

    def test_cancel_while_queued_runs_zero_rollouts(self, session, qsched):
        res = session["results"]["c1"]
        assert res.cancelled
        assert res.chunks == [] and res.scores == {}
        assert res.request_id == session["streams"]["c1"].request_id
        assert session["streams"]["c1"].request_id not in session["order"]
        assert qsched.stats()["qos"]["cancelled_queued"] == {"batch": 1}

    def test_latency_percentiles_per_class(self, session, qsched):
        lat = qsched.stats()["qos"]["latency"]
        assert lat["interactive"]["count"] == 1
        assert lat["batch"]["count"] == 2  # plug + b1; shed/cancel excluded
        for cls in ("interactive", "batch"):
            for metric in ("queue_s", "total_s"):
                block = lat[cls][metric]
                assert block["p95"] >= block["p50"] >= 0.0

    def test_queue_depth_per_class_empty_after_drain(self, session, qsched):
        assert qsched.stats()["qos"]["queue_depth"] == {
            "interactive": 0, "batch": 0}

    def test_qos_fields_leave_numerics_bit_identical(self, session, qsched):
        # a request that meets its (generous) deadline must be served
        # exactly like the no-QoS scheduler would serve it
        plain = qsched.submit(
            RequestSpec(**{**SPEC.to_dict(), "seed": 42})).result()
        qos = qsched.submit(RequestSpec(
            **{**SPEC.to_dict(), "seed": 42, "priority": "interactive",
               "deadline_ms": 600000.0, "degrade": True})).result()
        assert qos.degraded_members is None  # nowhere near the deadline
        assert set(plain.scores) == set(qos.scores)
        for name, arr in plain.scores.items():
            np.testing.assert_array_equal(qos.scores[name], arr,
                                          err_msg=name)


class TestAging:
    def test_aged_batch_request_beats_newer_interactive(self, pool):
        sched = ForecastScheduler(pool=pool, cache=ExecutableCache(),
                                  max_concurrency=1, aging_ms=200.0)
        try:
            order = _record_serve_order(sched)
            gate = _WarmGate(sched)
            plug = sched.submit(
                RequestSpec(**{**SPEC.to_dict(), "seed": 200}))
            assert gate.entered.wait(timeout=60)
            b1 = sched.submit(
                RequestSpec(**{**SPEC.to_dict(), "seed": 201}))
            time.sleep(0.3)  # b1 crosses aging_ms while queued
            i1 = sched.submit(RequestSpec(
                **{**SPEC.to_dict(), "seed": 202,
                   "priority": "interactive"}))
            gate.release.set()
            for st in (plug, b1, i1):
                st.result()
            # the aged batch request was promoted: FIFO within class 0
            assert order == [plug.request_id, b1.request_id,
                             i1.request_id]
        finally:
            sched.close()


class TestDegrade:
    def test_near_deadline_degrades_to_validated_floor(self, pool):
        # an absolute margin wider than the deadline => the degrade
        # policy latches at first pickup, deterministically
        sched = ForecastScheduler(pool=pool, cache=ExecutableCache(),
                                  max_concurrency=1,
                                  degrade_margin_ms=1e9)
        try:
            spec = RequestSpec(**{**SPEC.to_dict(), "members": 4,
                                  "degrade": True,
                                  "deadline_ms": 600000.0})
            res = sched.submit(spec).result()
            assert res.degraded_members == 2
            assert perturblib.validate_member_count(
                res.degraded_members, centered=True,
                cfg=spec.perturbation_config()) == []
            # the rollout really ran with 2 members: rank histogram has
            # E+1 = 3 bins, and only the members=2 engine was built
            assert res.scores["rank_hist"].shape[-1] == 3
            keys = set(sched._engines.snapshot())
            assert {k[1].members for k in keys} == {2}
            assert sched.stats()["qos"]["degraded"] == {"batch": 1}
        finally:
            sched.close()

    def test_no_degrade_without_opt_in(self, pool):
        sched = ForecastScheduler(pool=pool, cache=ExecutableCache(),
                                  max_concurrency=1,
                                  degrade_margin_ms=1e9)
        try:
            spec = RequestSpec(**{**SPEC.to_dict(), "members": 4,
                                  "deadline_ms": 600000.0})
            res = sched.submit(spec).result()
            assert res.degraded_members is None
            assert res.scores["rank_hist"].shape[-1] == 5
        finally:
            sched.close()


class TestBatchReforming:
    def test_straggler_joins_next_batch_of_its_shape(self, pool):
        sched = ForecastScheduler(pool=pool, cache=ExecutableCache(),
                                  max_concurrency=1, max_batch=2,
                                  batch_window_ms=50.0)
        try:
            sched.warmup(SPEC)
            sched.warmup(SPEC, batch=2)
            key = SPEC.batch_key()
            # stage an in-flight batch of this shape key
            with sched._cond:
                sched._inflight_keys[key] += 1
            r3 = sched.submit(RequestSpec(**{**SPEC.to_dict(),
                                             "seed": 301}))
            deadline = time.time() + 10
            while (sched.stats()["qos"]["requeued"].get("batch", 0) < 1
                   and time.time() < deadline):
                time.sleep(0.02)
            assert sched.stats()["qos"]["requeued"] == {"batch": 1}
            # the straggler parked instead of rolling solo...
            assert sched.stats()["batches"] == {}
            # ...and joins the next batch of its key
            r4 = sched.submit(RequestSpec(**{**SPEC.to_dict(),
                                             "seed": 302}))
            res3, res4 = r3.result(), r4.result()
            assert res3.batch_size == 2 and res4.batch_size == 2
            assert sched.stats()["batches"] == {"2": 1}
        finally:
            with sched._cond:
                sched._inflight_keys.pop(key, None)
                sched._cond.notify_all()
            sched.close()

    def test_no_park_without_inflight_batch(self, pool):
        sched = ForecastScheduler(pool=pool, cache=ExecutableCache(),
                                  max_concurrency=1, max_batch=2,
                                  batch_window_ms=50.0)
        try:
            sched.warmup(SPEC)
            res = sched.submit(RequestSpec(
                **{**SPEC.to_dict(), "seed": 303})).result()
            assert res.batch_size == 1
            assert sched.stats()["qos"]["requeued"] == {}
        finally:
            sched.close()


class TestCancellationShrink:
    def test_engine_shrinks_onto_warm_smaller_batch(self, pool):
        b = pool.get("smoke")
        spec = RequestSpec(**{**SPEC.to_dict(), "lead_chunk": 1,
                              "scored": False})
        eng = ForecastEngine(b.model, spec.engine_config())
        for nb in (3, 2):
            eng.compile_chunk(False, 1, b.params, b.buffers, batch=nb)
        state0s = [b.ds.state(i, 0) for i in range(3)]
        keys = [jax.random.PRNGKey(i) for i in range(3)]
        auxs = [lambda n: b.ds.aux_fields(6.0 * (n + 1))] * 3

        alive = [[0, 1, 2]]
        blocks = []
        for blk in eng.stream_batched(b.params, b.buffers, state0s, auxs,
                                      keys, steps=2,
                                      survivors=lambda: alive[0]):
            blocks.append(blk)
            alive[0] = [0, 2]  # request 1 cancels after chunk 0
        assert len(blocks) == 2
        assert all(r is not None for r in blocks[0])
        assert blocks[1][1] is None  # dropped slot stays positional
        assert blocks[1][0] is not None and blocks[1][2] is not None
        assert eng.dispatch_counts["shrinks"] == 1
        assert eng.dispatch_counts["jit"] == 0  # warm redispatch only
        assert eng.dispatch_counts["aot"] == 2

        # survivors' states are bit-identical to the unshrunk batch
        eng2 = ForecastEngine(b.model, spec.engine_config())
        eng2.compile_chunk(False, 1, b.params, b.buffers, batch=3)
        full = list(eng2.stream_batched(b.params, b.buffers, state0s,
                                        auxs, keys, steps=2))
        for j in (0, 2):
            np.testing.assert_array_equal(
                np.asarray(blocks[1][j].final_state),
                np.asarray(full[1][j].final_state))

    def test_engine_masks_when_smaller_batch_cold(self, pool):
        b = pool.get("smoke")
        spec = RequestSpec(**{**SPEC.to_dict(), "lead_chunk": 1,
                              "scored": False})
        eng = ForecastEngine(b.model, spec.engine_config())
        eng.compile_chunk(False, 1, b.params, b.buffers, batch=2)
        state0s = [b.ds.state(i, 0) for i in range(2)]
        keys = [jax.random.PRNGKey(i) for i in range(2)]
        auxs = [lambda n: b.ds.aux_fields(6.0 * (n + 1))] * 2
        alive = [[0, 1]]
        blocks = []
        for blk in eng.stream_batched(b.params, b.buffers, state0s, auxs,
                                      keys, steps=2,
                                      survivors=lambda: alive[0]):
            blocks.append(blk)
            alive[0] = [0]  # serial program NOT compiled -> stay masked
        assert eng.dispatch_counts["shrinks"] == 0
        assert all(r is not None for r in blocks[1])

    def test_scheduler_shrinks_cancelled_batch_member(self, pool):
        sched = ForecastScheduler(pool=pool, cache=ExecutableCache(),
                                  max_concurrency=1, max_batch=2,
                                  batch_window_ms=2000.0)
        try:
            sched.warmup(SPEC)             # serial program (shrink target)
            sched.warmup(SPEC, batch=2)    # the coalesced program
            gate = _WarmGate(
                sched, block_when=lambda a, k: k.get("batch") == 2)
            r1 = sched.submit(RequestSpec(**{**SPEC.to_dict(),
                                             "seed": 401}))
            r2 = sched.submit(RequestSpec(**{**SPEC.to_dict(),
                                             "seed": 402}))
            assert gate.entered.wait(timeout=60)  # batch of 2 picked
            r2.cancel()
            gate.release.set()
            res1, res2 = r1.result(), r2.result()
            assert res2.cancelled and res2.chunks == []
            assert not res1.cancelled
            assert sched.stats()["qos"]["batch_shrinks"] == 1
            eng = sched._engines.snapshot()[SPEC.engine_key()]
            assert eng.dispatch_counts["shrinks"] == 1
            assert eng.dispatch_counts["jit"] == 0
            # the survivor is bit-identical to a direct serial rollout
            b = pool.get("smoke")
            ref = ForecastEngine(b.model, SPEC.engine_config()).forecast(
                b.params, b.buffers, b.ds.state(0, 0),
                lambda n: b.ds.aux_fields(6.0 * (n + 1)),
                jax.random.PRNGKey(401), steps=SPEC.lead_steps,
                truth=lambda n: b.ds.state(0, n + 1))
            np.testing.assert_array_equal(res1.scores["crps"],
                                          np.asarray(ref.scores["crps"]))
        finally:
            sched.close()


class TestCloseUnblocksConsumers:
    def test_timed_out_close_pushes_terminal_errors(self, pool):
        sched = ForecastScheduler(pool=pool, cache=ExecutableCache(),
                                  max_concurrency=1)
        gate = _WarmGate(sched)
        r1 = sched.submit(RequestSpec(**{**SPEC.to_dict(), "seed": 500}))
        assert gate.entered.wait(timeout=60)  # worker stuck mid-serve
        r2 = sched.submit(RequestSpec(**{**SPEC.to_dict(), "seed": 501}))

        closer = threading.Thread(target=lambda: sched.close(timeout=1.0))
        closer.start()
        time.sleep(0.2)
        # distinct rejection while the drain is still in progress
        with pytest.raises(RuntimeError, match="draining"):
            sched.submit(RequestSpec(**{**SPEC.to_dict(), "seed": 502}))
        closer.join(timeout=30)
        assert not closer.is_alive()

        # every consumer unblocks with a terminal shutdown error --
        # the in-flight request AND the one still queued
        for st in (r1, r2):
            with pytest.raises(transport.ServingError) as err:
                st.result()
            assert err.value.reason == "shutdown"
        with pytest.raises(RuntimeError, match="scheduler is closed"):
            sched.submit(RequestSpec(**{**SPEC.to_dict(), "seed": 503}))
        # let the stuck worker die quickly instead of serving ghosts
        gate.abort = True
        gate.release.set()


class TestEvictionBuildRace:
    class _FakeEngine:
        def __init__(self, nbytes):
            self._n = nbytes

        def estimated_bytes(self):
            return self._n

    def test_build_locks_stable_across_eviction(self):
        pool = EnginePool(budget_bytes=100)
        pool.get_or_build("a", lambda: self._FakeEngine(80))
        lock_a = pool._build_locks["a"]
        pool.get_or_build("b", lambda: self._FakeEngine(80))
        assert pool.enforce_budget() == 1
        assert "a" not in pool.snapshot()
        # the evicted key's build lock is the SAME object: a builder
        # still holding it cannot race a fresh lock into existence
        assert pool._build_locks["a"] is lock_a

    def test_build_once_under_eviction_pressure(self):
        pool = EnginePool(budget_bytes=100)
        state = {k: {"active": 0, "max_active": 0, "builds": 0}
                 for k in ("a", "b")}
        mu = threading.Lock()

        def build(key):
            with mu:
                st = state[key]
                st["active"] += 1
                st["max_active"] = max(st["max_active"], st["active"])
            time.sleep(0.002)  # widen the window a popped lock would open
            with mu:
                state[key]["active"] -= 1
                state[key]["builds"] += 1
            return self._FakeEngine(80)

        stop = time.time() + 2.0
        errors = []

        def churn(key):
            try:
                while time.time() < stop:
                    pool.get_or_build(key, lambda: build(key))
                    pool.enforce_budget()  # evicts the other key
            except Exception as e:  # noqa: BLE001 -- surface in main thread
                errors.append(e)

        threads = [threading.Thread(target=churn, args=(k,))
                   for k in ("a", "b") for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        # rebuilds after eviction are legitimate; CONCURRENT builds of
        # one key never are
        for key, st in state.items():
            assert st["max_active"] == 1, (key, st)
            assert st["builds"] >= 1
