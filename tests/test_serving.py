"""Tests for the forecast serving subsystem (scheduler, executable
cache, NDJSON transport, HTTP service).

The load-bearing guarantees:

* fp32 results served through ``serving/`` -- including the NDJSON
  round-trip -- are **bit-identical** to a direct
  ``ForecastEngine.forecast`` with the same seed/config;
* a warm (cache-hit) request reports ``compile_s == 0`` and triggers no
  recompilation (every chunk dispatches the installed AOT executable);
* executable-cache keys distinguish exactly the fields that select a
  different compiled program;
* persisted (``jax.export``) executables reload in a fresh engine and
  reproduce the jit path bitwise.
"""

import dataclasses
import json
import threading

import jax
import numpy as np
import pytest

from repro.inference import ForecastEngine
from repro.serving import transport
from repro.serving.cache import ExecutableCache, ExecutableKey
from repro.serving.client import ForecastClient
from repro.serving.scheduler import (ForecastScheduler, ModelPool,
                                     RequestSpec)
from repro.serving.service import ForecastService

SPEC = RequestSpec(config="smoke", members=2, lead_steps=3, lead_chunk=2,
                   scored=True, return_state=True)


@pytest.fixture(scope="module")
def pool():
    return ModelPool()


@pytest.fixture(scope="module")
def sched(pool):
    s = ForecastScheduler(pool=pool, cache=ExecutableCache(),
                          max_concurrency=1)
    yield s
    s.close()


@pytest.fixture(scope="module")
def direct(pool):
    """Direct engine forecast with SPEC's config/seed -- the serving
    path must reproduce it bit-for-bit."""
    b = pool.get("smoke")
    eng = ForecastEngine(b.model, SPEC.engine_config())
    res = eng.forecast(b.params, b.buffers, b.ds.state(SPEC.sample, 0),
                       lambda n: b.ds.aux_fields(6.0 * (n + 1)),
                       jax.random.PRNGKey(SPEC.seed),
                       steps=SPEC.lead_steps,
                       truth=lambda n: b.ds.state(SPEC.sample, n + 1))
    return res


class TestRequestValidation:
    def test_odd_members_with_centering_rejected(self):
        with pytest.raises(ValueError, match="even member count"):
            RequestSpec(members=3).validate()

    def test_odd_members_with_perturbation_rejected(self):
        with pytest.raises(ValueError, match="even member count"):
            RequestSpec(members=5, perturb="obs").validate()

    def test_ensemble_transform_needs_bred_and_four_members(self):
        with pytest.raises(ValueError, match="bred"):
            RequestSpec(members=4, perturb="obs",
                        ensemble_transform=True).validate()
        with pytest.raises(ValueError, match="4 antithetic members"):
            RequestSpec(members=2, perturb="bred",
                        ensemble_transform=True).validate()

    def test_unknown_field_and_bad_values_rejected(self):
        with pytest.raises(ValueError, match="unknown request field"):
            RequestSpec.from_dict({"members": 2, "lead_step": 4})
        with pytest.raises(ValueError, match="unknown config"):
            RequestSpec(config="typo").validate()
        with pytest.raises(ValueError, match="lead_steps"):
            RequestSpec(lead_steps=0).validate()
        with pytest.raises(ValueError, match="precision"):
            RequestSpec(precision="float16").validate()

    def test_non_integer_numerics_rejected(self):
        # JSON is typed: members=2.0 or lead_steps=true must 400 up
        # front, not TypeError mid-rollout
        with pytest.raises(ValueError, match="members must be an integer"):
            RequestSpec(members=2.0).validate()
        with pytest.raises(ValueError, match="lead_steps must be an"):
            RequestSpec(lead_steps=True).validate()
        with pytest.raises(ValueError, match="scored must be a boolean"):
            RequestSpec(scored=1).validate()

    def test_validation_reports_every_problem_at_once(self):
        with pytest.raises(ValueError) as e:
            RequestSpec(config="typo", members=3, lead_chunk=0).validate()
        msg = str(e.value)
        assert "config" in msg and "member" in msg and "lead_chunk" in msg


class TestExecutableKeys:
    def test_keys_distinguish_compiled_programs(self, pool, sched):
        eng, _ = sched._get_engine(SPEC)

        def key(spec, scored=True, k=2):
            e, _ = sched._get_engine(spec)
            return ExecutableKey.for_engine(spec.config, e, scored, k)

        base = key(SPEC)
        assert base == key(RequestSpec(**SPEC.to_dict()))  # same shape
        # sample/seed/return_state do NOT change the executable
        assert base == key(RequestSpec(
            **{**SPEC.to_dict(), "sample": 9, "seed": 1,
               "return_state": False}))
        # every ISSUE-contract field does
        assert base != key(SPEC, scored=False)
        assert base != key(SPEC, k=1)
        assert base != key(RequestSpec(**{**SPEC.to_dict(), "members": 4}))
        assert base != key(RequestSpec(**{**SPEC.to_dict(),
                                          "lead_chunk": 3}))
        assert base != key(RequestSpec(**{**SPEC.to_dict(),
                                          "precision": "bfloat16"}))
        assert base != key(RequestSpec(**{**SPEC.to_dict(),
                                          "perturb": "obs"}))
        assert base != key(RequestSpec(**{**SPEC.to_dict(),
                                          "spectra": True}))
        # the kernel substrate selects a different compiled program, so
        # it must select a different executable-cache key
        assert base != key(RequestSpec(**{**SPEC.to_dict(),
                                          "kernels": "pallas"}))
        assert base != key(RequestSpec(**{**SPEC.to_dict(),
                                          "kernels": "reference"}))

    def test_kernel_config_changes_engine_and_cache_key(self, sched):
        from repro.inference import ForecastEngine
        from repro.kernels.config import KernelConfig
        b = sched.pool.get("smoke")
        eng_ref = ForecastEngine(b.model, SPEC.engine_config())
        cfg_pal = dataclasses.replace(
            SPEC.engine_config(),
            kernels=KernelConfig(sht="pallas", disco="pallas",
                                 interpret=True))
        eng_pal = ForecastEngine(b.model, cfg_pal)
        k_ref = ExecutableKey.for_engine("smoke", eng_ref, True, 2)
        k_pal = ExecutableKey.for_engine("smoke", eng_pal, True, 2)
        assert k_ref != k_pal
        assert k_ref.token() != k_pal.token()
        # and the engine re-homed its model on the requested substrate
        assert eng_pal.model.cfg.kernels.disco == "pallas"

    def test_invalid_kernels_value_rejected(self):
        with pytest.raises(ValueError, match="kernels must be one of"):
            RequestSpec(**{**SPEC.to_dict(), "kernels": "cuda"}).validate()

    def test_warm_hit_miss_accounting(self, pool):
        b = pool.get("smoke")
        eng = ForecastEngine(b.model, SPEC.engine_config())
        cache = ExecutableCache()
        key = ExecutableKey.for_engine("smoke", eng, True, 2)
        first = cache.warm(key, eng, b.params, b.buffers)
        assert not first["hit"] and first["source"] == "compiled"
        assert first["compile_s"] > 0
        second = cache.warm(key, eng, b.params, b.buffers)
        assert second["hit"] and second["source"] == "memory"
        assert second["compile_s"] == 0.0
        assert cache.stats()["misses"] == 1 and cache.stats()["hits"] == 1


class TestScheduler:
    def test_served_scores_bit_identical_to_direct(self, sched, direct):
        # Round-trip every event through the NDJSON encoding, so this
        # asserts transport exactness too (acceptance criterion).
        raw = sched.submit(SPEC).events()
        events = [json.loads(transport.dump_event(ev)) for ev in raw]
        res = transport.collect(iter(events))
        assert res.lead_steps.tolist() == [0, 1, 2]
        assert [c["lead_steps"] for c in res.chunks] == [[0, 1], [2]]
        for name, arr in direct.scores.items():
            np.testing.assert_array_equal(res.scores[name],
                                          np.asarray(arr), err_msg=name)
        np.testing.assert_array_equal(res.final_state,
                                      np.asarray(direct.final_state))

    def test_warm_request_no_recompilation(self, sched):
        before = sched.cache.stats()["misses"]
        res = sched.submit(SPEC).result()
        assert res.timing["compile_s"] == 0.0
        assert res.cache == {"hits": 2, "misses": 0}
        assert sched.cache.stats()["misses"] == before
        # every chunk call dispatched an installed executable -- the jit
        # (recompilation) path never ran on this warm engine
        eng = sched._engines.snapshot()[SPEC.engine_key()]
        assert eng.dispatch_counts["jit"] == 0
        assert eng.dispatch_counts["aot"] > 0

    def test_unscored_request_streams_without_scores(self, sched):
        spec = RequestSpec(**{**SPEC.to_dict(), "scored": False,
                              "return_state": True})
        res = sched.submit(spec).result()
        assert res.scores == {}
        assert res.final_state is not None

    def test_timing_report_fields(self, sched):
        res = sched.submit(SPEC).result()
        t = res.timing
        assert set(t) == {"queue_s", "setup_s", "compile_s", "run_s",
                          "total_s", "chunk_s", "batch_size"}
        assert len(t["chunk_s"]) == 2
        assert t["total_s"] >= t["run_s"] > 0
        assert t["batch_size"] == 1 and res.batch_size == 1

    def test_runtime_error_reaches_stream_as_error_event(self, sched,
                                                         monkeypatch):
        spec = RequestSpec(**{**SPEC.to_dict(), "seed": 123})
        monkeypatch.setattr(
            sched.cache, "warm_engine",
            lambda *a, **k: (_ for _ in ()).throw(RuntimeError("boom")))
        with pytest.raises(transport.ServingError, match="boom"):
            sched.submit(spec).result()


class TestCoalescing:
    """Same-shape requests batch into one rollout: bit-identical
    per-request streams, one batched compile, shape-key boundaries and
    mid-batch cancellation."""

    SAMPLES = (0, 3, 5, 2)
    SEEDS = (7, 9, 1, 4)

    def _specs(self, **overrides):
        return [RequestSpec(**{**SPEC.to_dict(), "sample": sm, "seed": sd,
                               **overrides})
                for sm, sd in zip(self.SAMPLES, self.SEEDS)]

    @pytest.fixture(scope="class")
    def coal(self, pool):
        s = ForecastScheduler(pool=pool, cache=ExecutableCache(),
                              max_concurrency=1, max_batch=4,
                              batch_window_ms=2000.0)
        yield s
        s.close()

    def test_four_coalesced_bit_identical_to_four_serial(self, pool, coal):
        # THE acceptance criterion: 4 coalesced same-shape requests,
        # NDJSON round-tripped, vs 4 serial ForecastEngine.forecast
        # runs -- bitwise equal, served by exactly one batched compile.
        misses_before = coal.cache.stats()["misses"]
        streams = [coal.submit(s) for s in self._specs()]
        results = []
        for st in streams:
            events = [json.loads(transport.dump_event(ev))
                      for ev in st.events()]
            results.append(transport.collect(iter(events)))
        stats = coal.stats()
        assert stats["batches"].get("4") == 1
        # one batched compile per distinct chunk length (2 and 1) --
        # NOT one per request
        assert coal.cache.stats()["misses"] - misses_before == 2
        eng = coal._engines.snapshot()[SPEC.engine_key()]
        assert eng.dispatch_counts["jit"] == 0
        assert eng.dispatch_counts["aot"] == 2

        b = pool.get("smoke")
        direct_eng = ForecastEngine(b.model, SPEC.engine_config())
        for spec, res in zip(self._specs(), results):
            ref = direct_eng.forecast(
                b.params, b.buffers, b.ds.state(spec.sample, 0),
                lambda n: b.ds.aux_fields(6.0 * (n + 1)),
                jax.random.PRNGKey(spec.seed), steps=spec.lead_steps,
                truth=lambda n: b.ds.state(spec.sample, n + 1))
            assert res.batch_size == 4
            assert res.timing["batch_size"] == 4
            for name, arr in ref.scores.items():
                np.testing.assert_array_equal(
                    res.scores[name], np.asarray(arr),
                    err_msg=f"sample={spec.sample} {name}")
            np.testing.assert_array_equal(res.final_state,
                                          np.asarray(ref.final_state))

    def test_warm_batch_zero_compile(self, coal):
        streams = [coal.submit(s) for s in self._specs()]
        results = [st.result() for st in streams]
        assert all(r.timing["compile_s"] == 0.0 for r in results)
        assert all(r.cache["misses"] == 0 for r in results)
        eng = coal._engines.snapshot()[SPEC.engine_key()]
        assert eng.dispatch_counts["jit"] == 0

    def test_max_batch_splits_overflow(self, pool):
        sched = ForecastScheduler(pool=pool, cache=ExecutableCache(),
                                  max_concurrency=1, max_batch=2,
                                  batch_window_ms=2000.0)
        try:
            streams = [sched.submit(s) for s in self._specs()]
            for st in streams:
                st.result()
            assert sched.stats()["batches"] == {"2": 2}
        finally:
            sched.close()

    def test_shape_key_boundary_not_coalesced(self, pool):
        sched = ForecastScheduler(pool=pool, cache=ExecutableCache(),
                                  max_concurrency=1, max_batch=4,
                                  batch_window_ms=500.0)
        try:
            a = RequestSpec(**{**SPEC.to_dict(), "seed": 1})
            b = RequestSpec(**{**SPEC.to_dict(), "lead_steps": 2,
                               "seed": 2})  # different rollout length
            streams = [sched.submit(a), sched.submit(b)]
            for st in streams:
                st.result()
            assert sched.stats()["batches"] == {"1": 2}
        finally:
            sched.close()

    def test_coalesce_opt_out(self, pool):
        sched = ForecastScheduler(pool=pool, cache=ExecutableCache(),
                                  max_concurrency=1, max_batch=4,
                                  batch_window_ms=500.0)
        try:
            specs = self._specs()[:2]
            solo = RequestSpec(**{**specs[0].to_dict(), "coalesce": False})
            streams = [sched.submit(solo), sched.submit(specs[1])]
            for st in streams:
                st.result()
            assert sched.stats()["batches"] == {"1": 2}
        finally:
            sched.close()

    def test_mid_batch_cancellation_masks_member(self, pool):
        sched = ForecastScheduler(pool=pool, cache=ExecutableCache(),
                                  max_concurrency=1, max_batch=2,
                                  batch_window_ms=2000.0)
        try:
            specs = self._specs()[:2]
            streams = [sched.submit(s) for s in specs]
            # cancel member 0 while the batch is still forming/serving:
            # it is masked out of chunk events; member 1 finishes whole
            streams[0].cancel()
            cancelled = streams[0].result()
            survivor = streams[1].result()
            assert cancelled.cancelled
            assert not survivor.cancelled
            assert survivor.lead_steps.tolist() == [0, 1, 2]
            assert len(cancelled.chunks) < len(survivor.chunks) or \
                cancelled.chunks == []
            b = pool.get("smoke")
            ref = ForecastEngine(b.model, specs[1].engine_config()).forecast(
                b.params, b.buffers, b.ds.state(specs[1].sample, 0),
                lambda n: b.ds.aux_fields(6.0 * (n + 1)),
                jax.random.PRNGKey(specs[1].seed),
                steps=specs[1].lead_steps,
                truth=lambda n: b.ds.state(specs[1].sample, n + 1))
            np.testing.assert_array_equal(survivor.scores["crps"],
                                          np.asarray(ref.scores["crps"]))
        finally:
            sched.close()


class TestEnginePoolBudget:
    """LRU eviction keeps the engine pool under its byte budget while
    warm keys survive."""

    def _spec(self, **overrides):
        return RequestSpec(**{**SPEC.to_dict(), **overrides})

    def test_lru_eviction_under_budget(self, pool):
        spec_a = self._spec()
        spec_b = self._spec(lead_chunk=3)
        spec_c = self._spec(members=4)
        # measure each warm engine's footprint on an unbudgeted pool,
        # then budget for exactly {A, C}: warming C must evict only the
        # LRU engine (B), never the warm one (A)
        probe = ForecastScheduler(pool=pool, cache=ExecutableCache(),
                                  max_concurrency=1)
        try:
            sizes = {}
            for name, spec in (("a", spec_a), ("b", spec_b),
                               ("c", spec_c)):
                probe.warmup(spec)
                snap = probe._engines.snapshot()
                sizes[name] = snap[spec.engine_key()].estimated_bytes()
        finally:
            probe.close()
        assert all(v > 0 for v in sizes.values())
        budget = sizes["a"] + sizes["c"] + (1 << 20)
        assert budget < sizes["a"] + sizes["b"] + sizes["c"]
        sched = ForecastScheduler(pool=pool, cache=ExecutableCache(),
                                  max_concurrency=1,
                                  engine_budget_bytes=budget)
        try:
            sched.warmup(spec_a)
            sched.warmup(spec_b)
            assert sched.stats()["pool"]["evictions"] == 0
            # touch A so B is the LRU victim when C overflows the pool
            sched.submit(spec_a).result()
            sched.warmup(spec_c)
            stats = sched.stats()["pool"]
            assert stats["engine_bytes"] <= budget
            assert stats["evictions"] == 1
            keys = set(sched._engines.snapshot())
            assert spec_a.engine_key() in keys  # warm key survived
            assert spec_c.engine_key() in keys
            assert spec_b.engine_key() not in keys  # LRU victim
        finally:
            sched.close()

    def test_stats_report_bytes_and_evictions(self, sched):
        stats = sched.stats()
        assert stats["pool"]["engine_budget_bytes"] is None
        assert stats["pool"]["evictions"] == 0
        assert stats["pool"]["engine_bytes"] > 0
        for eng in stats["engines"]:
            assert eng["estimated_bytes"] > 0
            assert {"aot", "jit", "h2d_chunks",
                    "h2d_steps"} <= set(eng["dispatch"])


class TestHTTPService:
    @pytest.fixture(scope="class")
    def server(self, sched):
        svc = ForecastService(scheduler=sched)
        srv = svc.make_server(port=0)
        thread = threading.Thread(target=srv.serve_forever, daemon=True)
        thread.start()
        yield srv
        srv.shutdown()
        srv.server_close()

    @pytest.fixture(scope="class")
    def client(self, server):
        return ForecastClient(port=server.server_address[1])

    def test_health_and_stats(self, client):
        assert client.health() == {"ok": True}
        stats = client.stats()
        assert stats["workers"] == 1 and "cache" in stats

    def test_chunk_by_chunk_delivery(self, client):
        events = list(client.stream(SPEC))
        kinds = [e["event"] for e in events]
        assert kinds == ["start", "chunk", "chunk", "done"]
        chunks = [e for e in events if e["event"] == "chunk"]
        assert [c["lead_steps"] for c in chunks] == [[0, 1], [2]]
        assert all("crps" in c["scores"] and "rank_hist" in c["scores"]
                   for c in chunks)

    def test_served_over_http_bit_identical(self, client, direct):
        res = client.forecast(SPEC)
        np.testing.assert_array_equal(res.scores["crps"],
                                      np.asarray(direct.scores["crps"]))
        np.testing.assert_array_equal(res.final_state,
                                      np.asarray(direct.final_state))

    def test_invalid_spec_is_http_400(self, client):
        with pytest.raises(transport.ServingError, match="400.*even"):
            list(client.stream({"members": 3}))

    def test_unknown_route_404(self, client):
        with pytest.raises(transport.ServingError, match="404"):
            client._get_json("/v1/nope")


class TestPersistedExecutables:
    def test_export_reload_bit_identical(self, pool, tmp_path, direct):
        b = pool.get("smoke")
        d = str(tmp_path / "aot")
        cache1 = ExecutableCache(persist_dir=d)
        eng1 = ForecastEngine(b.model, SPEC.engine_config())
        out1 = cache1.warm_engine("smoke", eng1, True, SPEC.lead_steps,
                                  b.params, b.buffers)
        assert [o["source"] for o in out1["outcomes"]] == ["compiled",
                                                           "compiled"]
        # a fresh engine + cache (a "new process") loads from disk
        cache2 = ExecutableCache(persist_dir=d)
        eng2 = ForecastEngine(b.model, SPEC.engine_config())
        out2 = cache2.warm_engine("smoke", eng2, True, SPEC.lead_steps,
                                  b.params, b.buffers)
        assert [o["source"] for o in out2["outcomes"]] == ["disk", "disk"]
        assert cache2.stats()["disk_hits"] == 2
        res = eng2.forecast(b.params, b.buffers, b.ds.state(SPEC.sample, 0),
                            lambda n: b.ds.aux_fields(6.0 * (n + 1)),
                            jax.random.PRNGKey(SPEC.seed),
                            steps=SPEC.lead_steps,
                            truth=lambda n: b.ds.state(SPEC.sample, n + 1))
        assert eng2.dispatch_counts["aot"] == 2
        assert eng2.dispatch_counts["jit"] == 0
        np.testing.assert_array_equal(np.asarray(res.final_state),
                                      np.asarray(direct.final_state))
        np.testing.assert_array_equal(np.asarray(res.scores["crps"]),
                                      np.asarray(direct.scores["crps"]))

    def test_stale_blob_recompiles_instead_of_poisoning(self, pool,
                                                        tmp_path, caplog):
        # A corrupt/incompatible persisted file must fall back to a
        # fresh compile and be quarantined aside (renamed *.corrupt),
        # not fail every request for its key until someone wipes the
        # directory -- see tests/test_faults.py for the full
        # quarantine/read-failure matrix.
        b = pool.get("smoke")
        d = str(tmp_path / "aot")
        cache = ExecutableCache(persist_dir=d)
        eng = ForecastEngine(b.model, SPEC.engine_config())
        key = ExecutableKey.for_engine("smoke", eng, True, 2)
        import logging
        import os
        os.makedirs(d, exist_ok=True)
        with open(cache._path(key), "wb") as f:
            f.write(b"not a stablehlo module")
        with caplog.at_level(logging.WARNING, "repro.serving.cache"):
            out = cache.warm(key, eng, b.params, b.buffers)
        assert not out["hit"] and out["source"] == "compiled"
        assert "quarantined corrupt executable" in caplog.text
        assert cache.stats()["quarantined"] == 1
        assert os.path.exists(cache._path(key) + ".corrupt")
        assert eng.has_chunk_executable(True, 2, b.params, b.buffers)
        # the bad file was replaced by a loadable one
        eng2 = ForecastEngine(b.model, SPEC.engine_config())
        out2 = ExecutableCache(persist_dir=d).warm(key, eng2, b.params,
                                                   b.buffers)
        assert out2["source"] == "disk"


class TestTransport:
    def test_array_roundtrip_exact(self):
        rng = np.random.default_rng(0)
        a = rng.normal(size=(3, 4, 5)).astype(np.float32)
        np.testing.assert_array_equal(
            transport.decode_array(transport.encode_array(a)), a)

    def test_float32_survives_json_exactly(self):
        rng = np.random.default_rng(1)
        vals = rng.normal(size=257).astype(np.float32) * 1e-7
        rt = np.asarray(json.loads(json.dumps(vals.tolist())), np.float32)
        np.testing.assert_array_equal(rt, vals)

    def test_collect_raises_on_error_event(self):
        with pytest.raises(transport.ServingError, match="nope"):
            transport.collect(iter([{"event": "error", "message": "nope"}]))

    def test_collect_raises_on_truncated_stream(self):
        # close-delimited framing: a dead server is EOF, which must not
        # pass for a completed forecast
        truncated = [{"event": "start", "request_id": "r9", "spec": {}},
                     {"event": "chunk", "request_id": "r9", "index": 0,
                      "lead_steps": [0], "scores": {"crps": [[1.0]]}}]
        with pytest.raises(transport.ServingError, match="without a"):
            transport.collect(iter(truncated))

    def test_half_written_line_raises_serving_error(self):
        import io
        fp = io.BytesIO(b'{"event":"start","request_id":"r0"}\n{"event":"ch')
        with pytest.raises(transport.ServingError, match="corrupt NDJSON"):
            list(transport.read_events(fp))
