"""Tests for the spherical signal-processing substrate (paper Appendix B)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.sphere import disco, grids, interp, legendre, noise, sht, spectral_conv


# ---------------------------------------------------------------------------
# Grids & quadrature (B.1)
# ---------------------------------------------------------------------------

class TestGrids:
    @pytest.mark.parametrize("kind", ["equiangular", "gauss"])
    def test_weights_positive_and_sum_to_sphere_area(self, kind):
        g = grids.make_grid(37, 72, kind)
        assert (g.quad_weights > 0).all()
        total = g.cell_area.sum() * g.nlon
        np.testing.assert_allclose(total, 4 * np.pi, rtol=1e-10)

    def test_gauss_exact_for_polynomials(self):
        # GL quadrature integrates cos(theta)^k exactly for k <= 2n-1.
        g = grids.make_grid(8, 16, "gauss")
        for k in range(0, 15):
            f = np.cos(g.colat)[:, None] ** k * np.ones((1, g.nlon))
            got = grids.quad_integrate(g, f)
            exact = 2 * np.pi * (1 + (-1) ** k) / (k + 1)
            np.testing.assert_allclose(got, exact, atol=1e-12)

    def test_equiangular_includes_poles(self):
        g = grids.make_grid(721, 1440, "equiangular")
        assert g.colat[0] == 0.0 and np.isclose(g.colat[-1], np.pi)


# ---------------------------------------------------------------------------
# Legendre & SHT (B.3)
# ---------------------------------------------------------------------------

class TestSHT:
    def test_legendre_orthonormal_on_gauss(self):
        g = grids.make_grid(24, 48, "gauss")
        p = legendre.legendre_table(24, 24, g.colat)
        for m in [0, 1, 5]:
            gram = np.einsum("h,hl,hk->lk", g.quad_weights,
                             p[:, :, m], p[:, :, m]) * 2 * np.pi
            valid = np.arange(24) >= m
            expect = np.diag(valid.astype(float))
            np.testing.assert_allclose(gram, expect, atol=1e-10)

    def test_roundtrip_gauss_exact(self):
        g = grids.make_grid(32, 64, "gauss")
        t = sht.SHT.create(g)
        rng = np.random.default_rng(0)
        c = (rng.normal(size=(t.lmax, t.mmax))
             + 1j * rng.normal(size=(t.lmax, t.mmax)))
        c *= sht.mode_mask(t.lmax, t.mmax)
        c[:, 0] = c[:, 0].real
        x = t.inverse(jnp.asarray(c, jnp.complex64))
        c2 = np.asarray(t.forward(x))
        np.testing.assert_allclose(c2, c, atol=2e-5)

    def test_parseval(self):
        g = grids.make_grid(32, 64, "gauss")
        t = sht.SHT.create(g)
        x = jax.random.normal(jax.random.PRNGKey(1), (32, 64))
        c = t.forward(x)
        x_bl = t.inverse(c)  # band-limited projection of x
        integ = grids.quad_integrate(g, np.asarray(x_bl) ** 2)
        np.testing.assert_allclose(
            integ, np.asarray(sht.spectrum(c)).sum(), rtol=1e-5)

    def test_convolution_theorem(self):
        # Zonal filter acts diagonally: isht(sht(x) * k_l) equals the
        # continuous group convolution with the axisymmetric filter.
        g = grids.make_grid(24, 48, "gauss")
        t = sht.SHT.create(g)
        key = jax.random.PRNGKey(2)
        x = t.inverse(t.forward(jax.random.normal(key, (24, 48))))
        kl = jnp.exp(-0.05 * jnp.arange(t.lmax) ** 2)
        y = t.inverse(t.forward(x) * kl[:, None])
        # Rotation about the z axis commutes with the zonal convolution:
        shift = 7
        y_rot = t.inverse(t.forward(jnp.roll(x, shift, axis=-1)) * kl[:, None])
        np.testing.assert_allclose(np.asarray(jnp.roll(y, shift, axis=-1)),
                                   np.asarray(y_rot), atol=1e-4)

    def test_resample_preserves_bandlimited(self):
        g1 = grids.make_grid(24, 48, "gauss")
        g2 = grids.make_grid(48, 96, "gauss")
        t1, t2 = sht.SHT.create(g1), sht.SHT.create(g2)
        x = t1.inverse(t1.forward(jax.random.normal(jax.random.PRNGKey(3), (24, 48))))
        up = sht.resample(x, t1, t2)
        back = sht.resample(up, t2, t1)
        np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(nlat=st.integers(8, 24), seed=st.integers(0, 2**31 - 1))
    def test_roundtrip_property(self, nlat, seed):
        g = grids.make_grid(nlat, 2 * nlat, "gauss")
        t = sht.SHT.create(g)
        x = jax.random.normal(jax.random.PRNGKey(seed), (nlat, 2 * nlat))
        xb = t.inverse(t.forward(x))
        xbb = t.inverse(t.forward(xb))
        np.testing.assert_allclose(np.asarray(xbb), np.asarray(xb),
                                   atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# DISCO convolutions (B.5)
# ---------------------------------------------------------------------------

class TestDisco:
    def setup_method(self):
        self.gi = grids.make_grid(64, 128, "equiangular")
        self.go = grids.make_grid(32, 64, "gauss")
        self.plan = disco.make_disco_plan(self.gi, self.go)

    def test_shapes(self):
        assert self.plan.psi.shape[0] == self.plan.n_basis == 7
        assert self.plan.stride == 2
        x = jnp.ones((64, 128))
        z = disco.disco_conv(x, jnp.asarray(self.plan.psi),
                             jnp.asarray(self.plan.lat_idx), self.plan.stride)
        assert z.shape == (7, 32, 64)

    def test_zonal_symmetry_on_constant(self):
        # A constant field convolved with any filter must be zonally constant.
        x = jnp.ones((64, 128))
        z = disco.disco_conv(x, jnp.asarray(self.plan.psi),
                             jnp.asarray(self.plan.lat_idx), self.plan.stride)
        assert float(jnp.std(z, axis=-1).max()) < 1e-4

    def test_localization(self):
        # Delta input produces response only within the cutoff radius.
        d = jnp.zeros((64, 128)).at[32, 64].set(1.0)
        r = disco.disco_conv(d, jnp.asarray(self.plan.psi),
                             jnp.asarray(self.plan.lat_idx), self.plan.stride)
        r = np.asarray(jnp.abs(r).sum(axis=0))
        src = self.gi.colat[32]
        for h in range(32):
            if abs(self.go.colat[h] - src) > 2.5 * self.plan.theta_cutoff:
                assert r[h].max() < 1e-6, h

    def test_longitude_equivariance(self):
        # Rotation about z commutes with the spherical group convolution.
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (64, 128))
        buf = self.plan.buffers()
        z1 = disco.disco_conv(jnp.roll(x, 4, axis=-1), buf["psi"],
                              buf["lat_idx"], self.plan.stride)
        z2 = jnp.roll(disco.disco_conv(x, buf["psi"], buf["lat_idx"],
                                       self.plan.stride), 2, axis=-1)
        np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), atol=1e-4)

    def test_grouped_channel_separation(self):
        # groups == c_in: output channel o only depends on input channel o//k.
        key = jax.random.PRNGKey(1)
        p = disco.init_disco_conv(key, 4, 4, self.plan.n_basis, groups=4,
                                  bias=False)
        x = jax.random.normal(key, (1, 4, 64, 128))
        y0 = disco.apply_disco_conv(p, x, self.plan.buffers(),
                                    self.plan.stride, groups=4)
        x2 = x.at[:, 1].set(0.0)
        y1 = disco.apply_disco_conv(p, x2, self.plan.buffers(),
                                    self.plan.stride, groups=4)
        np.testing.assert_allclose(np.asarray(y0[:, 0]), np.asarray(y1[:, 0]),
                                   atol=1e-6)
        assert float(jnp.abs(y0[:, 1] - y1[:, 1]).max()) > 1e-3


# ---------------------------------------------------------------------------
# Interpolation (B.6)
# ---------------------------------------------------------------------------

class TestInterp:
    def test_identity_on_same_grid(self):
        g = grids.make_grid(16, 32, "equiangular")
        r = interp.BilinearResample.create(g, g)
        x = jax.random.normal(jax.random.PRNGKey(0), (3, 16, 32))
        np.testing.assert_allclose(np.asarray(r(x)), np.asarray(x), atol=1e-6)

    def test_exact_for_bilinear_functions(self):
        # f(theta, phi) linear in theta & phi-independent is reproduced.
        gi = grids.make_grid(33, 64, "equiangular")
        go = grids.make_grid(21, 32, "gauss")
        f = jnp.asarray(gi.colat)[:, None] * jnp.ones((1, 64))
        r = interp.BilinearResample.create(gi, go)
        out = np.asarray(r(f))
        np.testing.assert_allclose(out, go.colat[:, None] * np.ones((1, 32)),
                                   rtol=1e-5)

    def test_constant_preserved_with_pole_handling(self):
        gi = grids.make_grid(20, 40, "gauss")  # no pole rows
        go = grids.make_grid(41, 80, "equiangular")  # has pole rows
        r = interp.BilinearResample.create(gi, go)
        out = np.asarray(r(jnp.ones((20, 40))))
        np.testing.assert_allclose(out, 1.0, atol=1e-6)


# ---------------------------------------------------------------------------
# Spherical diffusion noise (B.7)
# ---------------------------------------------------------------------------

class TestNoise:
    def setup_method(self):
        g = grids.make_grid(24, 48, "gauss")
        self.sd = noise.SphericalDiffusion(sht=sht.SHT.create(g),
                                           k_t=(1e-3, 1e-2), lam=1.0)

    def test_stationary_variance(self):
        # Paper (28): stationary pointwise std is sigma/sqrt(2) under the
        # orthonormal-harmonics convention used here.
        z = self.sd.to_grid(self.sd.init_state(jax.random.PRNGKey(0), (256,)))
        std = float(jnp.std(z[:, 0]))
        assert abs(std - 1 / np.sqrt(2)) < 0.05, std

    def test_ar1_temporal_correlation(self):
        key = jax.random.PRNGKey(1)
        s = self.sd.init_state(key, (512,))
        s2 = self.sd.step(jax.random.PRNGKey(2), s)
        z1 = np.asarray(self.sd.to_grid(s)).ravel()
        z2 = np.asarray(self.sd.to_grid(s2)).ravel()
        corr = np.corrcoef(z1, z2)[0, 1]
        np.testing.assert_allclose(corr, np.exp(-1.0), atol=0.05)

    def test_length_scales_order(self):
        # larger k_T -> smoother field -> smaller mean squared gradient proxy.
        z = self.sd.to_grid(self.sd.init_state(jax.random.PRNGKey(3), (64,)))
        rough = [float(jnp.mean(jnp.diff(z[:, i], axis=-1) ** 2))
                 for i in range(2)]
        assert rough[0] > rough[1]

    def test_noise_centering(self):
        z = self.sd.to_grid(self.sd.init_state(jax.random.PRNGKey(4), (4,)))
        c = noise.center_noise(z, axis=0)
        np.testing.assert_allclose(np.asarray(c[1]), -np.asarray(c[0]))
        np.testing.assert_allclose(np.asarray(c[0]), np.asarray(z[0]))
        np.testing.assert_allclose(np.asarray(c[3]), -np.asarray(z[2]))


class TestSpectralConv:
    def test_depthwise_is_diagonal(self):
        g = grids.make_grid(16, 32, "gauss")
        t = sht.SHT.create(g)
        p = spectral_conv.init_spectral_filter(jax.random.PRNGKey(0), 3, 3,
                                               t.lmax, mode="depthwise")
        x = jax.random.normal(jax.random.PRNGKey(1), (3, 16, 32))
        y = spectral_conv.apply_spectral_conv(p, x, t.buffers(), 32)
        # identity gains => band-limited projection of x
        xb = t.inverse(t.forward(x))
        np.testing.assert_allclose(np.asarray(y), np.asarray(xb), atol=1e-4)

    def test_full_mixing_shape_and_scale(self):
        g = grids.make_grid(16, 32, "gauss")
        t = sht.SHT.create(g)
        p = spectral_conv.init_spectral_filter(jax.random.PRNGKey(0), 8, 4,
                                               t.lmax)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 16, 32))
        y = spectral_conv.apply_spectral_conv(p, x, t.buffers(), 32)
        assert y.shape == (2, 8, 16, 32)
        assert 0.2 < float(y.std()) < 5.0
