"""Tests for optimizer, data pipeline, checkpointing and the trainer."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import fcn3 as fcn3cfg
from repro.core.fcn3 import FCN3
from repro.data import era5_synthetic as dlib
from repro.optim import adam as adamlib
from repro.train import checkpoint as ckpt
from repro.train import trainer as trlib


class TestAdam:
    def test_quadratic_convergence(self):
        opt = adamlib.Adam(lr=0.1)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(200):
            grads = jax.tree.map(lambda p: 2 * p, params)  # d/dp p^2
            params, state = opt.update(params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_halving_schedule(self):
        s = adamlib.halving_schedule(1.0, 10)
        assert float(s(jnp.asarray(5))) == 1.0
        assert float(s(jnp.asarray(10))) == 0.5
        assert float(s(jnp.asarray(25))) == 0.25

    def test_clip_by_global_norm(self):
        g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
        c = adamlib.clip_by_global_norm(g, 1.0)
        np.testing.assert_allclose(float(adamlib.global_norm(c)), 1.0,
                                   rtol=1e-5)

    def test_matches_reference_adam_one_step(self):
        # hand-computed first Adam step: delta = lr * g/|g| (bias-corrected)
        opt = adamlib.Adam(lr=0.5, eps=0.0)
        p = {"w": jnp.asarray([1.0])}
        s = opt.init(p)
        p2, _ = opt.update(p, {"w": jnp.asarray([0.3])}, s)
        np.testing.assert_allclose(np.asarray(p2["w"]), [0.5], atol=1e-4)


class TestSyntheticData:
    def setup_method(self):
        self.cfg = fcn3cfg.fcn3_smoke()
        self.ds = dlib.SyntheticERA5(self.cfg)

    def test_deterministic(self):
        a = self.ds.state(7)
        b = self.ds.state(7)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
        c = self.ds.state(8)
        assert float(jnp.abs(a - c).max()) > 1e-3

    def test_shapes_and_water_positive(self):
        x = self.ds.state(0)
        assert x.shape == (self.cfg.n_state, self.cfg.nlat, self.cfg.nlon)
        w = self.cfg.water_channel_indices()
        assert float(x[w].min()) >= 0.0

    def test_temporal_persistence(self):
        # AR(1): consecutive steps correlate strongly, distant ones less.
        x0 = np.asarray(self.ds.state(3, 0)).ravel()
        x1 = np.asarray(self.ds.state(3, 1)).ravel()
        x9 = np.asarray(self.ds.state(3, 9)).ravel()
        c1 = np.corrcoef(x0, x1)[0, 1]
        c9 = np.corrcoef(x0, x9)[0, 1]
        assert c1 > 0.85 and c9 < c1 - 0.15

    def test_red_spectrum(self):
        # synoptic peak + power-law decay: high-l power << low-l power.
        from repro.core.sphere import sht as shtlib
        t = self.ds.sht
        psd = np.asarray(shtlib.spectrum(t.forward(self.ds.state(1)[0])))
        assert psd[2:6].mean() > 30 * psd[-4:].mean()

    def test_zenith_angle_bounds_and_cycle(self):
        cz0 = dlib.cos_zenith_angle(self.ds.grid.colat, self.ds.grid.lons,
                                    0.0)
        cz12 = dlib.cos_zenith_angle(self.ds.grid.colat, self.ds.grid.lons,
                                     12.0)
        assert cz0.min() >= 0.0 and cz0.max() <= 1.0
        assert float(np.abs(cz0 - cz12).max()) > 0.3  # day/night shift

    def test_sharded_loader_partitions_batch(self):
        full = dlib.Loader(self.ds, global_batch=4, rank=0, world=1)
        r0 = dlib.Loader(self.ds, global_batch=4, rank=0, world=2)
        r1 = dlib.Loader(self.ds, global_batch=4, rank=1, world=2)
        bf = next(iter(full))
        b0 = next(iter(r0))
        b1 = next(iter(r1))
        np.testing.assert_allclose(np.asarray(bf["state"][:2]),
                                   np.asarray(b0["state"]))
        np.testing.assert_allclose(np.asarray(bf["state"][2:]),
                                   np.asarray(b1["state"]))

    def test_lat_sharded_loader(self):
        l0 = dlib.Loader(self.ds, global_batch=2, lat_shard=(0, 2))
        b = next(iter(l0))
        assert b["state"].shape[-2] == self.cfg.nlat // 2


class TestCheckpoint:
    def test_roundtrip_and_manifest(self, tmp_path):
        params = {"layers": [{"w": jnp.arange(6.0).reshape(2, 3)}],
                  "scale": jnp.asarray(2.0)}
        opt = adamlib.Adam()
        state = opt.init(params)
        path = ckpt.save_checkpoint(
            str(tmp_path), 42, params, state,
            shardings={"params/layers/0/w": [None, "model"]})
        assert ckpt.latest_checkpoint(str(tmp_path)) == path
        template = jax.tree.map(jnp.zeros_like,
                                {"params": params, "opt_state": state})
        restored, manifest = ckpt.restore_checkpoint(path, template)
        assert manifest["step"] == 42
        np.testing.assert_allclose(
            np.asarray(restored["params"]["layers"][0]["w"]),
            np.arange(6.0).reshape(2, 3))
        assert manifest["shardings"]["params/layers/0/w"] == [None, "model"]

    def test_shape_mismatch_rejected(self, tmp_path):
        params = {"w": jnp.zeros((2, 2))}
        path = ckpt.save_checkpoint(str(tmp_path), 0, params)
        bad = {"params": {"w": jnp.zeros((3, 3))}}
        with pytest.raises(ValueError):
            ckpt.restore_checkpoint(path, bad)


class TestEnsembleTrainer:
    def setup_method(self):
        self.cfg = fcn3cfg.fcn3_smoke()
        self.model = FCN3(self.cfg)
        self.ds = dlib.SyntheticERA5(self.cfg)
        self.cw = fcn3cfg.channel_weights(self.cfg.n_levels)

    def _batch(self, rollout=1, batch=1):
        loader = dlib.Loader(self.ds, global_batch=batch, rollout=rollout)
        return next(iter(loader))

    @pytest.mark.slow
    def test_loss_decreases_over_steps(self):
        tcfg = trlib.TrainConfig(ensemble_size=2, rollout_steps=1, lr=2e-3)
        tr = trlib.EnsembleTrainer(self.model, tcfg, self.cw)
        buffers = self.model.make_buffers()
        batch = self._batch()
        params = self.model.init_calibrated(
            jax.random.PRNGKey(0), batch["state"],
            jnp.concatenate([batch["aux"][:, 0],
                             self.model.sample_noise(jax.random.PRNGKey(1),
                                                     (1,))], axis=1),
            buffers)
        opt_state = tr.optimizer.init(params)
        step = jax.jit(tr.make_train_step(buffers))
        losses = []
        for i in range(8):
            params, opt_state, aux = step(params, opt_state, batch,
                                          jax.random.PRNGKey(i))
            losses.append(float(aux["loss"]))
            assert np.isfinite(losses[-1])
        assert losses[-1] < losses[0], losses

    @pytest.mark.slow
    def test_rollout_training_runs(self):
        tcfg = trlib.TrainConfig(ensemble_size=2, rollout_steps=2,
                                 fair_crps=True, noise_centering=True)
        tr = trlib.EnsembleTrainer(self.model, tcfg, self.cw)
        buffers = self.model.make_buffers()
        batch = self._batch(rollout=2)
        params = self.model.init(jax.random.PRNGKey(0))
        opt_state = tr.optimizer.init(params)
        step = jax.jit(tr.make_train_step(buffers))
        params, opt_state, aux = step(params, opt_state, batch,
                                      jax.random.PRNGKey(0))
        assert np.isfinite(float(aux["loss"]))
        assert "nodal_1" in aux  # both rollout steps contributed

    def test_eval_step_metrics(self):
        tcfg = trlib.TrainConfig(ensemble_size=2)
        tr = trlib.EnsembleTrainer(self.model, tcfg, self.cw)
        buffers = self.model.make_buffers()
        params = self.model.init(jax.random.PRNGKey(0))
        ev = jax.jit(tr.make_eval_step(buffers, n_members=3))
        out = ev(params, self._batch(), jax.random.PRNGKey(1))
        assert np.isfinite(float(out["crps"]))
        assert np.isfinite(float(out["rmse_ens_mean"]))

    def test_wdt_estimate(self):
        samples = jnp.stack([jnp.stack([self.ds.state(i, k)
                                        for k in range(2)])
                             for i in range(2)])
        w = trlib.estimate_wdt(samples)
        assert w.shape == (self.cfg.n_state,)
        assert (w > 0).all()
